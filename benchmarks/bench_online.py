"""Online estimation subsystem benchmark.  Writes ``BENCH_online.json``.

Three measurements:

1. incremental-update throughput — one ``update_task_batch`` observation
   vs a full ``fit_task_batch`` refit at ~1000 tasks (the re-prediction
   hot path during execution), plus the ``lax.scan`` stream rate;
2. incremental-vs-refit equivalence — max relative difference of the
   predictive means/stds after a shuffled stream (x64, so the gap is
   algorithmic, not float32);
3. static-plan vs online re-scheduling — makespan and cumulative MPE
   trajectory of the event-driven executor across the paper's five
   workflows on the heterogeneous cluster (ground truth carries the
   simulator's systematic per-(task, node) efficiency the initial factor
   adjustment cannot see — exactly what streaming observations recover).
   Four arms per workflow: static (frozen predictions), online without
   the bias layer (the PR 2 loop), online with the per-(task, node)
   bias posterior + same-tick batching + bias-coupled straggler copies
   (the PR 3 loop), and the risk-aware arm — bias + empirical-Bayes
   sigma_r pooling + uncertainty-priced HEFT (effective cost
   mean + risk_k * widened sigma) + tail-mass speculative admission.
   The bias arm must beat the PR 2 arm's final MPE on most workflows
   (the systematic efficiency IS a per-pair multiplicative bias), and
   the risk arm must win or tie the bias arm's final makespan on most
   workflows (pricing posterior width steers work off jittery pairs).

A fourth section (``faults``) sweeps the default crash scenario — two
nodes dying mid-run plus a ~5% per-attempt failure probability — and
checks that the fault-tolerant loop (retries with capped backoff,
censored observations, Beta-Binomial reliability pricing) completes
100% of every workflow within a committed makespan-inflation bound,
while the frozen static plan strands the dead nodes' work.

A fifth section (``scale``) sweeps the (T, N) estimate-matrix size to
~1M cells and the stacked workflow axis W to 64: steady-state per-tick
wall time of the fused ``tick_step`` engine vs the legacy
observe → update → bias scatter → dirty-row re-predict sequence (same
observation batches, per-phase spans through the ``repro.obs`` lanes),
plus the vmapped/sharded fleet tick's cell throughput.  The gate
asserts the fused tick beats legacy by ``SCALE_MIN_SPEEDUP``x at the
100k-cell point.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import LotaruEstimator, TickEngine, blr, build_state, \
    get_node, profile_cluster, profile_node, target_nodes
from repro.core.estimator import FittedTask
from repro.core.profiler import BenchResult
from repro.launch.mesh import make_fleet_mesh
from repro.obs import (EventLog, calibration_summary, observe_records,
                       tick_latency_summary)
from repro.data.synthetic import synthetic_dag
from repro.online import OnlineExecutor, fanout_chain_dag
from repro.online.fleet import fleet_tick_step, shard_fleet, stack_states
from repro.sched.heft import (CommCosts, heft_schedule_array,
                              realized_makespan)
from repro.sched.simulator import (ClusterSimulator, FaultInjector,
                                   GridEngine, Topology)
from repro.sched.workflows import INPUTS, WORKFLOWS, dag_edge_gb

OUT = Path(__file__).resolve().parents[1] / "BENCH_online.json"
TRACES = Path(__file__).resolve().parents[1] / "traces"

#: calibration gate inputs: coverage of the 90% predictive interval must
#: land in CAL_BAND once CAL_MIN_OBS observations have streamed in (the
#: warm-up reflects the near-prior posterior, not the online estimator)
CAL_MIN_OBS = 20
CAL_BAND = (0.80, 0.98)
Z90 = 1.6448536269514722     # Phi^-1(0.95): the 90% two-sided z quantile


def _synthetic_samples(n_tasks: int, n_samples: int = 8, seed: int = 0):
    rng = np.random.default_rng(seed)
    sizes_list, runtimes_list = [], []
    for i in range(n_tasks):
        sizes = np.geomspace(1.0, 256.0, n_samples) * rng.uniform(0.5, 2.0)
        if rng.random() < 0.7:
            rts = (rng.uniform(0.1, 5.0) * sizes + rng.uniform(1, 50)
                   + rng.normal(0, 0.05, n_samples))
        else:
            rts = rng.uniform(20, 200) + rng.normal(0, 0.5, n_samples)
        sizes_list.append(sizes)
        runtimes_list.append(np.abs(rts))
    return sizes_list, runtimes_list


def bench_update_throughput(n_tasks: int = 1000, n_updates: int = 500):
    sizes_list, runtimes_list = _synthetic_samples(n_tasks)
    model = blr.fit_task_batch(sizes_list, runtimes_list)

    # full-refit steady state (the seed's only way to absorb a sample)
    reps = 3
    blr.fit_task_batch(sizes_list, runtimes_list)        # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        blr.fit_task_batch(sizes_list, runtimes_list)
    refit_s = (time.perf_counter() - t0) / reps

    # single-observation updates: warm the jit, then time row-scattered
    # updates (repeats allowed — log growth is host-side and amortised)
    rng = np.random.default_rng(1)
    model = blr.update_task_batch(model, 0, 300.0, 400.0)   # compile
    rows = rng.integers(0, n_tasks, n_updates)
    xs = rng.uniform(1, 300, n_updates)
    ys = rng.uniform(1, 500, n_updates)
    jax.block_until_ready(model.post.mu)
    t0 = time.perf_counter()
    for r, x, y in zip(rows, xs, ys):
        model = blr.update_task_batch(model, int(r), float(x), float(y))
    jax.block_until_ready(model.post.mu)
    update_s = (time.perf_counter() - t0) / n_updates

    # scanned stream (no per-observation Python dispatch); the stream
    # consumes its model (shared sample log), so warm and timed runs each
    # get a fresh fit — the scan jit cache is shared between them
    stream_n = 4 * n_updates
    idx = rng.integers(0, n_tasks, stream_n)
    sx = rng.uniform(1, 300, stream_n)
    sy = rng.uniform(1, 500, stream_n)
    warm = blr.fit_task_batch(sizes_list, runtimes_list)
    m = blr.update_task_batch_stream(warm, idx, sx, sy)      # warm scan
    jax.block_until_ready(m.post.mu)
    model2 = blr.fit_task_batch(sizes_list, runtimes_list)
    t0 = time.perf_counter()
    m = blr.update_task_batch_stream(model2, idx, sx, sy)
    jax.block_until_ready(m.post.mu)
    stream_s = (time.perf_counter() - t0) / stream_n

    return {
        "n_tasks": n_tasks,
        "refit_s": refit_s,
        "update_s": update_s,
        "stream_update_s": stream_s,
        "update_speedup_vs_refit": refit_s / update_s,
        "stream_speedup_vs_refit": refit_s / stream_s,
        "stream_obs_per_s": 1.0 / stream_s,
    }


def bench_equivalence(n_tasks: int = 200, per_task: int = 5, seed: int = 2):
    rng = np.random.default_rng(seed)
    sizes_list, runtimes_list = _synthetic_samples(n_tasks, seed=seed)
    model = blr.fit_task_batch(sizes_list, runtimes_list)
    stream = [(int(rng.integers(0, n_tasks)), float(rng.uniform(1, 400)),
               float(rng.uniform(1, 600)))
              for _ in range(per_task * n_tasks)]
    m_inc = blr.update_task_batch_stream(
        model, [s[0] for s in stream], [s[1] for s in stream],
        [s[2] for s in stream])
    concat_s = [np.concatenate([sizes_list[i],
                                [s[1] for s in stream if s[0] == i]])
                for i in range(n_tasks)]
    concat_r = [np.concatenate([runtimes_list[i],
                                [s[2] for s in stream if s[0] == i]])
                for i in range(n_tasks)]
    m_ref = blr.fit_task_batch(concat_s, concat_r)
    worst_mean = worst_std = 0.0
    for xq in (2.0, 64.0, 350.0):
        mi, si = blr.predict_task_batch(m_inc, xq)
        mr, sr = blr.predict_task_batch(m_ref, xq)
        worst_mean = max(worst_mean, float(np.max(
            np.abs(np.asarray(mi) - np.asarray(mr))
            / np.maximum(np.abs(np.asarray(mr)), 1e-12))))
        worst_std = max(worst_std, float(np.max(
            np.abs(np.asarray(si) - np.asarray(sr))
            / np.maximum(np.abs(np.asarray(sr)), 1e-12))))
    gate_equal = bool((np.asarray(m_inc.correlated)
                       == np.asarray(m_ref.correlated)).all())
    return {"n_tasks": n_tasks, "stream_len": len(stream),
            "max_rel_diff_mean": worst_mean, "max_rel_diff_std": worst_std,
            "pearson_gate_equal": gate_equal}


RISK_K = 1.0        # risk-aware arm: effective cost = mean + RISK_K * sigma
SPEC_TAIL = 0.8     # tail-mass admission: P(bias > drift) >= 0.8


def _calibration(events) -> dict:
    """Per-workflow calibration record for the gate: both coverage forms
    of the 90% predictive interval, post-warm-up.  ``coverage90`` scores
    the executor's own t-intervals (the surprise-gate bounds);
    ``coverage90_z`` scores ``pred_mean ± Z90 * pred_std`` — the Gaussian
    interval implied by the σ that ``risk_k`` pricing and tail-mass
    speculation actually consume, which is what the gate checks."""
    cal = calibration_summary(events, min_obs=CAL_MIN_OBS)
    recs = observe_records(events)[CAL_MIN_OBS:]
    if recs:
        cov_z = float(np.mean([
            abs(r["runtime"] - r["pred_mean"]) <= Z90 * r["pred_std"]
            for r in recs]))
    else:
        cov_z = float("nan")
    return {"n_obs": cal["n_obs"], "min_obs": CAL_MIN_OBS,
            "coverage90": cal["coverage"], "coverage90_z": cov_z,
            "coverage90_all": cal["coverage_all"],
            "sharpness_rel": cal["sharpness_rel"],
            "pit_tv": cal["pit_tv"]}


def _in_band(r: dict) -> bool:
    return (r["calibration_n_obs"] >= CAL_MIN_OBS
            and CAL_BAND[0] <= r["coverage90_z"] <= CAL_BAND[1])


def bench_workflows(n_samples: int = 8, nodes_per_type: int = 2,
                    seed: int = 0, trace_dir: Path | None = TRACES):
    local = get_node("local-cpu")
    local_bench = profile_node(local, np.random.default_rng(seed + 7))
    tbenches = profile_cluster(target_nodes(), seed=seed + 13)
    truth = ClusterSimulator(seed=seed + 2000)
    results = {}
    observability: dict = {}
    overhead = None
    for wf in WORKFLOWS:
        size = INPUTS[(wf, 1)]
        by_name = {t.name: t for t in WORKFLOWS[wf]}
        tasks, task_name = fanout_chain_dag(list(by_name), n_samples)
        # deterministic ground truth per (instance, node type): realised
        # runtimes carry noise + the hidden systematic efficiency
        truth_tab = {(tid, nt.name): truth.run_task(by_name[task_name[tid]],
                                                    nt, size)
                     for tid in tasks for nt in target_nodes()}

        def make_executor(online: bool, bias_correction: bool = True,
                          risk: bool = False, tracer=None):
            sim = ClusterSimulator(seed=seed)     # same local runs each time
            est = LotaruEstimator(local_bench, tbenches,
                                  bias_correction=bias_correction,
                                  bias_empirical_bayes=risk)
            est.fit_tasks(list(by_name), size,
                          lambda n, s, cf: sim.run_task(by_name[n], local, s,
                                                        cpu_factor=cf))
            grid = GridEngine.from_types(nodes_per_type=nodes_per_type)
            return OnlineExecutor(
                est, tasks, task_name, size, grid,
                lambda tid, node: truth_tab[(tid, grid.type_of(node).name)],
                online=online, confidence=0.9,
                risk_k=RISK_K if risk else 0.0,
                spec_tail=SPEC_TAIL if risk else None, tracer=tracer)

        # clear the jit cache between arms: every arm compiles its own
        # spread of XLA executables (one scan per distinct tick batch
        # size, one HEFT solve per frontier shape) and the leftover
        # modules exhaust the kernel's vm.max_map_count long before
        # they exhaust memory
        static = make_executor(online=False).run()
        jax.clear_caches()
        nobias = make_executor(online=True, bias_correction=False).run()
        jax.clear_caches()
        if overhead is None:
            # tracing overhead, measured once: the same online arm with
            # no tracer attached, timed cold (fresh jit cache) like the
            # traced run below — the delta is what the EventLog costs
            t0 = time.perf_counter()
            make_executor(online=True).run()
            wall_plain = time.perf_counter() - t0
            jax.clear_caches()
        log = EventLog()
        t0 = time.perf_counter()
        online = make_executor(online=True, tracer=log).run()
        wall_traced = time.perf_counter() - t0
        if overhead is None:
            overhead = {"workflow": wf, "wall_untraced_s": wall_plain,
                        "wall_traced_s": wall_traced,
                        "overhead_frac": wall_traced / wall_plain - 1.0,
                        "n_events": len(log.events),
                        "per_event_us": (wall_traced - wall_plain)
                        / max(len(log.events), 1) * 1e6}
        jax.clear_caches()
        risk = make_executor(online=True, risk=True).run()
        if trace_dir is not None:
            trace_dir.mkdir(parents=True, exist_ok=True)
            log.to_jsonl(trace_dir / f"{wf}.jsonl")
            log.to_chrome(trace_dir / f"{wf}.chrome.json")
        cal = _calibration(log.events)
        lat = tick_latency_summary(log.events)
        observability[wf] = {"n_events": len(log.events),
                             "tick_latency": lat}
        traj_s = static.cumulative_mpe()
        traj_o = online.cumulative_mpe()
        results[wf] = {
            "instances": len(tasks),
            "makespan_static": static.makespan,
            "makespan_online_nobias": nobias.makespan,
            "makespan_online": online.makespan,
            "makespan_online_risk": risk.makespan,
            "mpe_static": static.final_mpe(),
            "mpe_online_nobias": nobias.final_mpe(),
            "mpe_online": online.final_mpe(),
            "mpe_online_risk": risk.final_mpe(),
            "mpe_traj_static_first_last": [float(traj_s[0]),
                                           float(traj_s[-1])],
            "mpe_traj_online_first_last": [float(traj_o[0]),
                                           float(traj_o[-1])],
            "replans": online.replans,
            "surprises": online.surprises,
            "speculations": online.speculations,
            "spec_wins": online.spec_wins,
            "risk_replans": risk.replans,
            "risk_speculations": risk.speculations,
            "risk_spec_wins": risk.spec_wins,
            "calibration_n_obs": cal["n_obs"],
            "coverage90": cal["coverage90"],
            "coverage90_z": cal["coverage90_z"],
            "calibration": cal,
        }
        # every workflow/arm combination compiles its own set of XLA
        # executables (frontier sizes vary per re-plan); left to
        # accumulate across the sweep they exhaust the kernel's
        # vm.max_map_count before they exhaust memory
        jax.clear_caches()
    wins = sum(1 for r in results.values()
               if r["mpe_online"] < r["mpe_static"])
    bias_wins = sum(1 for r in results.values()
                    if r["mpe_online"] < r["mpe_online_nobias"])
    makespan_wins = sum(1 for r in results.values()
                        if r["makespan_online"] <= r["makespan_static"])
    # win-or-tie: risk pricing may leave a placement unchanged (same
    # argmin), which is success, not failure — ties count
    risk_makespan_wins = sum(
        1 for r in results.values()
        if r["makespan_online_risk"] <= r["makespan_online"] * (1 + 1e-9))
    calibration_in_band = sum(1 for r in results.values() if _in_band(r))
    return {"workflows": results, "n_samples": n_samples,
            "nodes_per_type": nodes_per_type,
            "risk_k": RISK_K, "spec_tail": SPEC_TAIL,
            "online_mpe_wins": wins, "bias_mpe_wins": bias_wins,
            "online_makespan_wins": makespan_wins,
            "risk_makespan_wins": risk_makespan_wins,
            "calibration_in_band": calibration_in_band,
            "cal_min_obs": CAL_MIN_OBS, "cal_band": list(CAL_BAND),
            "n_workflows": len(results),
            "observability": {"per_workflow": observability,
                              "overhead": overhead,
                              "trace_dir": (str(trace_dir)
                                            if trace_dir else None)}}


FAULT_P = 0.05           # base per-attempt failure probability
FAULT_REL_K = 1.0        # reliability pricing: 1/(E[p] - k*sd)
FAULT_MAX_ATTEMPTS = 6   # per-task attempt budget
INFLATION_BOUND = 2.5    # FT makespan <= bound * fault-free makespan


def bench_fault_tolerance(n_samples: int = 8, nodes_per_type: int = 2,
                          seed: int = 0):
    """Fifth arm: the default crash sweep — two nodes die mid-run and
    every attempt carries a ~5% failure probability.  The fault-tolerant
    loop (retry + backoff + censored observations + reliability-priced
    HEFT) must complete 100% of every workflow with bounded makespan
    inflation over its own fault-free run, while the static plan strands
    whatever its dead nodes owned."""
    local = get_node("local-cpu")
    local_bench = profile_node(local, np.random.default_rng(seed + 7))
    tbenches = profile_cluster(target_nodes(), seed=seed + 13)
    truth = ClusterSimulator(seed=seed + 2000)
    results = {}
    for wf in WORKFLOWS:
        size = INPUTS[(wf, 1)]
        by_name = {t.name: t for t in WORKFLOWS[wf]}
        tasks, task_name = fanout_chain_dag(list(by_name), n_samples)
        truth_tab = {(tid, nt.name): truth.run_task(by_name[task_name[tid]],
                                                    nt, size)
                     for tid in tasks for nt in target_nodes()}

        def make_executor(online: bool, faults=None, strict: bool = True):
            sim = ClusterSimulator(seed=seed)     # same local runs each time
            est = LotaruEstimator(local_bench, tbenches,
                                  bias_correction=True,
                                  bias_empirical_bayes=True)
            est.fit_tasks(list(by_name), size,
                          lambda n, s, cf: sim.run_task(by_name[n], local, s,
                                                        cpu_factor=cf))
            grid = GridEngine.from_types(nodes_per_type=nodes_per_type)
            return OnlineExecutor(
                est, tasks, task_name, size, grid,
                lambda tid, node: truth_tab[(tid, grid.type_of(node).name)],
                online=online, confidence=0.9,
                risk_k=RISK_K, spec_tail=SPEC_TAIL,
                faults=faults, rel_k=FAULT_REL_K,
                max_attempts=FAULT_MAX_ATTEMPTS, strict=strict)

        ref = make_executor(online=True).run()    # fault-free reference
        jax.clear_caches()   # see bench_workflows: bounds mmap growth
        names = list(GridEngine.from_types(
            nodes_per_type=nodes_per_type).nodes)
        crash = {names[0]: 0.25 * ref.makespan,
                 names[-1]: 0.5 * ref.makespan}

        def faults():
            return FaultInjector(crash_at=crash, p_fail=FAULT_P,
                                 seed=seed + 31)

        ft = make_executor(online=True, faults=faults()).run()
        jax.clear_caches()
        static = make_executor(online=False, faults=faults(),
                               strict=False).run()
        results[wf] = {
            "instances": len(tasks),
            "makespan_ref": ref.makespan,
            "makespan_ft": ft.makespan,
            "inflation": ft.makespan / ref.makespan,
            "ft_completed_fraction": ft.completed_fraction(),
            "static_completed_fraction": static.completed_fraction(),
            "failures": ft.failures,
            "retries": ft.retries,
            "lost_nodes": ft.lost_nodes,
            "censored": len(ft.censored),
            "ft_replans": ft.replans,
        }
        jax.clear_caches()   # see bench_workflows: bounds mmap growth
    complete = sum(1 for r in results.values()
                   if r["ft_completed_fraction"] >= 1.0)
    max_inflation = max(r["inflation"] for r in results.values())
    static_strands = sum(1 for r in results.values()
                         if r["static_completed_fraction"] < 1.0)
    return {"workflows": results, "n_samples": n_samples,
            "nodes_per_type": nodes_per_type,
            "p_fail": FAULT_P, "rel_k": FAULT_REL_K,
            "max_attempts": FAULT_MAX_ATTEMPTS,
            "inflation_bound": INFLATION_BOUND,
            "ft_complete": complete, "max_inflation": max_inflation,
            "static_strands": static_strands,
            "n_workflows": len(results)}


# ---------------------------------------------------------------------------
# data-locality arm (PR 10): comm-aware vs comm-blind HEFT on a cross-rack
# cluster, judged by REALIZED makespan; plus the 10k-task scheduling smoke
# ---------------------------------------------------------------------------
LOC_INTRA_GBPS = 10.0    # same-rack bandwidth
LOC_CROSS_GBPS = 0.05    # oversubscribed cross-rack uplink (200x slower)
LOC_DATA_SCALE = 64.0    # edge-volume multiplier: a heavy-data regime
LOC_N_ZONES = 2
LOC_SCALE_MIN_TASKS = 10_000   # the synthetic stress DAG's size floor
LOC_LATENCY_BOUND_S = 30.0     # ... and its schedule-latency ceiling


def _scatter_gather_dag(chain: list[str], n_samples: int):
    """Per-sample scatter/gather instances: the first abstract task is
    the sample's source (QC/staging on the raw input), every middle task
    consumes ITS output in parallel, and the last task (the multiqc-like
    report) gathers them all.  Unlike ``fanout_chain_dag`` — where each
    chain happily serialises on one node and no data ever moves — the
    parallel middle stage MUST spread across nodes, so the source's
    output gets copied and placement faces the real locality trade."""
    from repro.sched.heft import SchedTask
    tasks: dict[str, SchedTask] = {}
    task_name: dict[str, str] = {}
    for s in range(n_samples):
        src, snk = f"s{s}.{chain[0]}", f"s{s}.{chain[-1]}"
        tasks[src] = SchedTask(id=src)
        task_name[src] = chain[0]
        for nm in chain[1:-1]:
            tid = f"s{s}.{nm}"
            tasks[tid] = SchedTask(id=tid, pred=[src])
            tasks[src].succ.append(tid)
            task_name[tid] = nm
        tasks[snk] = SchedTask(id=snk,
                               pred=[f"s{s}.{nm}" for nm in chain[1:-1]])
        for nm in chain[1:-1]:
            tasks[f"s{s}.{nm}"].succ.append(snk)
        task_name[snk] = chain[-1]
    return tasks, task_name


def bench_locality(n_samples: int = 6, nodes_per_type: int = 2,
                   seed: int = 0) -> dict:
    """Sixth arm: data-aware placement on a two-rack cluster.

    Both planners see the SAME noise-free runtime truth; the comm-aware
    one additionally prices per-edge transfer costs (``CommCosts`` over
    the rack topology's secs-per-GB matrix).  Neither plan's own
    optimistic makespan is trusted — both are replayed through
    ``realized_makespan`` under the true transfer prices, so the
    cross-rack copies the blind planner ignored show up in its number.
    The gate: comm-aware realized makespan must win on >= 3/5 workflows
    and never lose by more than 2% (greedy EFT with a transfer term can
    make myopic calls; a bigger regression means mispricing).  A second
    record schedules a >= 10k-task synthetic
    DAG (the WfCommons-style generator) comm-aware and reports the
    latency, bounding the O(T·N + E·N) claim."""
    truth = ClusterSimulator(seed=seed + 2000)
    results = {}
    for wf in WORKFLOWS:
        size = INPUTS[(wf, 1)]
        by_name = {t.name: t for t in WORKFLOWS[wf]}
        tasks, task_name = _scatter_gather_dag(list(by_name), n_samples)
        grid = GridEngine.from_types(nodes_per_type=nodes_per_type)
        names = grid.names()
        # contiguous blocks: each node TYPE lives in one rack, so the
        # fastest hardware is concentrated — chasing speed rack-blind
        # means dragging data across the slow link
        topo = Topology.blocks(names, LOC_N_ZONES,
                               intra_gbps=LOC_INTRA_GBPS,
                               cross_gbps=LOC_CROSS_GBPS)
        spg = topo.secs_per_gb(names)
        ids = list(tasks)
        idx = {tid: i for i, tid in enumerate(ids)}
        succ = [[idx[s] for s in tasks[t].succ] for t in ids]
        pred = [[idx[p] for p in tasks[t].pred] for t in ids]
        cost = np.array([[truth.expected_task_runtime(
            by_name[task_name[tid]], grid.type_of(n), size)
            for n in names] for tid in ids])
        eg = {(idx[p], idx[s]): g * LOC_DATA_SCALE
              for (p, s), g in dag_edge_gb(tasks, task_name, by_name,
                                           size).items()}
        comm = CommCosts(pred, eg, spg)
        blind = heft_schedule_array(succ, pred, cost)
        aware = heft_schedule_array(succ, pred, cost, comm=comm)
        T = len(ids)
        mk = {}
        cross = {}
        for label, s in (("blind", blind), ("aware", aware)):
            dur = cost[np.arange(T), s["assignment"]]
            mk[label] = realized_makespan(succ, pred, dur, s["assignment"],
                                          s["order"], comm=comm)
            cross[label] = sum(
                1 for t in range(T) for p in pred[t]
                if topo.zone(names[s["assignment"][p]])
                != topo.zone(names[s["assignment"][t]]))
        results[wf] = {
            "instances": T,
            "makespan_blind": mk["blind"],
            "makespan_aware": mk["aware"],
            "plan_makespan_blind": blind["makespan"],
            "plan_makespan_aware": aware["makespan"],
            "cross_rack_edges_blind": cross["blind"],
            "cross_rack_edges_aware": cross["aware"],
            "win": mk["aware"] < mk["blind"],
        }
    wins = sum(1 for r in results.values() if r["win"])
    return {"workflows": results, "n_samples": n_samples,
            "nodes_per_type": nodes_per_type, "n_zones": LOC_N_ZONES,
            "intra_gbps": LOC_INTRA_GBPS, "cross_gbps": LOC_CROSS_GBPS,
            "data_scale": LOC_DATA_SCALE,
            "locality_wins": wins, "n_workflows": len(results),
            "scale": locality_scale(seed=seed)}


def locality_scale(seed: int = 0, n_nodes: int = 16,
                   width: int = 100, depth: int = 140) -> dict:
    """Schedule a >= 10k-task synthetic DAG comm-aware and time the
    solve — the time-bounded scaling smoke CI runs standalone."""
    dag = synthetic_dag(width=width, depth=depth, fanout=2.0, seed=seed)
    rng = np.random.default_rng(seed + 5)
    speeds = rng.uniform(0.5, 2.0, n_nodes)
    cost = dag.cost_matrix(speeds)
    names = [f"n{j}" for j in range(n_nodes)]
    topo = Topology.split(names, 4, intra_gbps=LOC_INTRA_GBPS,
                          cross_gbps=LOC_CROSS_GBPS)
    comm = CommCosts(dag.pred, dag.edge_dict(), topo.secs_per_gb(names))
    t0 = time.perf_counter()
    sched = heft_schedule_array(dag.succ, dag.pred, cost, comm=comm)
    schedule_s = time.perf_counter() - t0
    return {"n_tasks": dag.n_tasks, "n_edges": dag.n_edges,
            "n_nodes": n_nodes, "min_tasks": LOC_SCALE_MIN_TASKS,
            "schedule_s": schedule_s,
            "latency_bound_s": LOC_LATENCY_BOUND_S,
            "makespan": sched["makespan"]}


# ---------------------------------------------------------------------------
# scale arm (PR 9): fused tick vs the legacy four-dispatch tick at (T, N),
# plus the vmapped (W, T, N) fleet sweep
# ---------------------------------------------------------------------------
SCALE_BATCH = 64         # observations per tick — both arms see the SAME ones
SCALE_WARM = 2           # warm-up ticks (compile + cache priming), untimed
SCALE_TICKS = 5          # timed steady-state ticks per point
SCALE_SIZE = 64.0        # shared input size of the sweep
SCALE_GATE_CELLS = 100_000   # gate point: fused must win here
SCALE_MIN_SPEEDUP = 5.0      # ... by at least this factor

#: gate-mode (T, N) points — (2048, 50) is the 102 400-cell gate point
SCALE_POINTS_GATE = [(256, 16), (2048, 50)]
#: the full sweep adds the ~1M-cell ceiling
SCALE_POINTS_FULL = SCALE_POINTS_GATE + [(1024, 64), (4096, 256)]


def _scale_bench(name: str, rng) -> BenchResult:
    return BenchResult(node=name,
                       cpu_events_s=float(rng.uniform(300.0, 900.0)),
                       matmul_gflops=float(rng.uniform(50.0, 200.0)),
                       mem_gbps=float(rng.uniform(10.0, 40.0)),
                       io_read_mbps=float(rng.uniform(200.0, 800.0)),
                       io_write_mbps=float(rng.uniform(200.0, 800.0)),
                       link_gbps=0.0)


def _scale_estimator(n_tasks: int, n_nodes: int, seed: int = 0):
    """A real ``LotaruEstimator`` at arbitrary (T, N): synthetic benches
    for N nodes, one ``fit_task_batch`` solve for T tasks injected as
    ``FittedTask``s (the batch cache is primed with the same fit, exactly
    like ``fit_tasks``) — the paper's five workflows top out at T=14, so
    the sweep needs shapes the workflow registry cannot provide."""
    rng = np.random.default_rng(seed)
    local = _scale_bench("local-cpu", rng)
    nodes = [f"n{j}" for j in range(n_nodes)]
    benches = {n: _scale_bench(n, rng) for n in nodes}
    est = LotaruEstimator(local, benches, bias_correction=True,
                          bias_empirical_bayes=True)
    sizes_list, runtimes_list = _synthetic_samples(n_tasks, seed=seed)
    batch = blr.fit_task_batch(sizes_list, runtimes_list)
    names = [f"t{i}" for i in range(n_tasks)]
    ws = rng.uniform(0.2, 0.95, n_tasks)
    for i, (name, model) in enumerate(zip(names,
                                          blr.unstack_task_models(batch))):
        est.tasks[name] = FittedTask(model=model, w=float(ws[i]),
                                     sizes=np.asarray(sizes_list[i]),
                                     runtimes=np.asarray(runtimes_list[i]))
    est._batch_cache = (names, [est.tasks[n] for n in names], batch,
                        np.asarray(ws, np.float64))
    return est, names, nodes


def _scale_obs(names, nodes, rng, batch: int):
    """One tick's worth of (task, node, size, runtime) observations.

    Tasks are drawn WITHOUT replacement so every tick dirties the same
    number of distinct rows — the legacy dirty-row re-predict compiles
    one executable per distinct-row count, and a steady-state comparison
    must not charge it a recompile per tick."""
    rows = rng.choice(len(names), size=min(batch, len(names)),
                      replace=False)
    return [(names[int(r)],
             nodes[int(rng.integers(0, len(nodes)))],
             SCALE_SIZE, float(rng.uniform(5.0, 120.0)))
            for r in rows]


def _scale_point(t: int, n: int, seed: int = 0) -> dict:
    """Steady-state per-tick wall time of both tick implementations at
    (T, N): legacy = ``observe_batch`` + dirty-row ``predict_matrix``
    (four dispatches stitched by Python), fused = ``TickEngine`` (one
    donated ``tick_step``).  Same observation batches, per-phase spans
    through the ``repro.obs`` lanes."""
    rng = np.random.default_rng(seed + 17)
    batches = [_scale_obs([f"t{i}" for i in range(t)],
                          [f"n{j}" for j in range(n)], rng, SCALE_BATCH)
               for _ in range(SCALE_WARM + SCALE_TICKS)]

    def drive(tick):
        for b in batches[:SCALE_WARM]:
            tick(b)
        t0 = time.perf_counter()
        for b in batches[SCALE_WARM:]:
            tick(b)
        return (time.perf_counter() - t0) / SCALE_TICKS

    est, _names, nodes = _scale_estimator(t, n, seed=seed)
    log_l = EventLog()
    est.set_tracer(log_l)
    est.predict_matrix(nodes, SCALE_SIZE)          # prime cache + compile

    def legacy_tick(b):
        est.observe_batch(b)
        m, _s = est.predict_matrix(nodes, SCALE_SIZE)
        return m

    legacy_s = drive(legacy_tick)
    jax.clear_caches()

    est2, _names, nodes = _scale_estimator(t, n, seed=seed)
    log_f = EventLog()
    engine = TickEngine(est2, nodes, size=SCALE_SIZE, tracer=log_f)

    def fused_tick(b):
        engine.observe_batch(b)
        m, _s = engine.predict_matrix(nodes, SCALE_SIZE)
        return m

    fused_s = drive(fused_tick)
    jax.clear_caches()
    return {"t": t, "n": n, "cells": t * n, "batch": SCALE_BATCH,
            "legacy_tick_s": legacy_s, "fused_tick_s": fused_s,
            "speedup": legacy_s / fused_s,
            "phases_legacy": tick_latency_summary(log_l.events),
            "phases_fused": tick_latency_summary(log_f.events)}


def _fleet_point(w: int, t: int, n: int, seed: int = 0) -> dict:
    """Throughput of the vmapped fleet tick over W stacked workflows,
    sharded across whatever devices the mesh exposes when the W axis
    divides (a single device replicates — today's layout)."""
    est, _names, nodes = _scale_estimator(t, n, seed=seed)
    state, _sn = build_state(est, nodes)
    fleet = stack_states([state] * w)
    mesh = make_fleet_mesh(task=1)
    wf_axis = dict(mesh.shape)["wf"]
    sharded = w % wf_axis == 0
    if sharded:
        fleet = shard_fleet(fleet, mesh)
    rng = np.random.default_rng(seed + 23)
    sizes = np.full(w, SCALE_SIZE)

    def tick_obs():
        rows = rng.integers(0, t, (w, SCALE_BATCH))
        cols = rng.integers(0, n, (w, SCALE_BATCH))
        y = rng.uniform(5.0, 120.0, (w, SCALE_BATCH))
        obs = np.zeros((w, SCALE_BATCH, 8))
        obs[..., 0] = rows
        obs[..., 1] = cols
        obs[..., 2] = SCALE_SIZE
        obs[..., 3] = y
        obs[..., 5] = y                  # med/spr: any consistent history
        obs[..., 6] = 1.0
        obs[..., 7] = 1.0
        return obs

    for _ in range(SCALE_WARM):
        fleet, mean, _std = fleet_tick_step(fleet, tick_obs(), sizes)
    jax.block_until_ready(mean)
    t0 = time.perf_counter()
    for _ in range(SCALE_TICKS):
        fleet, mean, _std = fleet_tick_step(fleet, tick_obs(), sizes)
    jax.block_until_ready(mean)
    tick_s = (time.perf_counter() - t0) / SCALE_TICKS
    jax.clear_caches()
    return {"w": w, "t": t, "n": n, "cells": w * t * n,
            "devices": len(jax.devices()), "sharded": sharded,
            "mesh_wf": wf_axis, "tick_s": tick_s,
            "cells_per_s": w * t * n / tick_s}


def bench_scale(points=None, fleet_ws=None, *, fleet_t: int = 128,
                fleet_n: int = 16, seed: int = 0) -> dict:
    points = SCALE_POINTS_FULL if points is None else points
    fleet_ws = [4, 16, 64] if fleet_ws is None else fleet_ws
    pts = [_scale_point(t, n, seed=seed) for t, n in points]
    fleets = [_fleet_point(w, fleet_t, fleet_n, seed=seed)
              for w in fleet_ws]
    gate_pts = [p for p in pts if p["cells"] >= SCALE_GATE_CELLS]
    gate_speedup = min((p["speedup"] for p in gate_pts),
                       default=float("nan"))
    return {"batch": SCALE_BATCH, "warm_ticks": SCALE_WARM,
            "timed_ticks": SCALE_TICKS, "size": SCALE_SIZE,
            "gate_cells": SCALE_GATE_CELLS,
            "min_speedup": SCALE_MIN_SPEEDUP,
            "points": pts, "fleet": fleets,
            "gate_speedup": gate_speedup}


def run(n_tasks: int = 1000, n_samples: int = 8,
        nodes_per_type: int = 2, scale_points=None,
        fleet_ws=None) -> list[tuple]:
    thr = bench_update_throughput(n_tasks=n_tasks)
    eq = bench_equivalence(n_tasks=max(50, n_tasks // 5))
    wf = bench_workflows(n_samples=n_samples, nodes_per_type=nodes_per_type)
    fl = bench_fault_tolerance(n_samples=n_samples,
                               nodes_per_type=nodes_per_type)
    jax.clear_caches()
    loc = bench_locality(n_samples=max(n_samples, 4),
                         nodes_per_type=nodes_per_type)
    sc = bench_scale(points=scale_points, fleet_ws=fleet_ws)
    result = {"config": {"n_tasks": n_tasks, "x64": True},
              "throughput": thr, "equivalence": eq, "execution": wf,
              "faults": fl, "locality": loc, "scale": sc}
    OUT.write_text(json.dumps(result, indent=2))
    print(f"update: {thr['update_s']*1e6:.0f}us/obs vs refit "
          f"{thr['refit_s']*1e3:.1f}ms -> "
          f"{thr['update_speedup_vs_refit']:.0f}x "
          f"(scan stream: {thr['stream_obs_per_s']:.0f} obs/s, "
          f"{thr['stream_speedup_vs_refit']:.0f}x)")
    print(f"equivalence: max rel mean={eq['max_rel_diff_mean']:.2e} "
          f"std={eq['max_rel_diff_std']:.2e} "
          f"gate_equal={eq['pearson_gate_equal']}")
    for name, r in wf["workflows"].items():
        print(f"  {name:10s} MPE {r['mpe_static']:.3f} -> "
              f"{r['mpe_online_nobias']:.3f} (PR2) -> "
              f"{r['mpe_online']:.3f} (bias) -> "
              f"{r['mpe_online_risk']:.3f} (risk)  "
              f"makespan {r['makespan_static']:.0f} "
              f"-> {r['makespan_online']:.0f} "
              f"-> {r['makespan_online_risk']:.0f} (risk)  "
              f"(replans {r['replans']}/{r['surprises']} surprises, "
              f"{r['speculations']} spec/{r['spec_wins']} won; risk "
              f"{r['risk_speculations']} spec)")
    print(f"online MPE wins: {wf['online_mpe_wins']}/{wf['n_workflows']}  "
          f"bias-vs-PR2 wins: {wf['bias_mpe_wins']}/{wf['n_workflows']}  "
          f"risk makespan win-or-tie: "
          f"{wf['risk_makespan_wins']}/{wf['n_workflows']}")
    for name, r in wf["workflows"].items():
        c = r["calibration"]
        print(f"  {name:10s} calibration: coverage90 t={c['coverage90']:.3f}"
              f" z={c['coverage90_z']:.3f} (n={c['n_obs']}, "
              f"warm-up {c['min_obs']})  sharpness_rel="
              f"{c['sharpness_rel']:.2f}  pit_tv={c['pit_tv']:.2f}")
    ov = wf["observability"]["overhead"]
    print(f"calibration in band {wf['cal_band']}: "
          f"{wf['calibration_in_band']}/{wf['n_workflows']}  tracing "
          f"overhead ({ov['workflow']}): {ov['overhead_frac']:+.1%} "
          f"({ov['n_events']} events, {ov['per_event_us']:.1f}us/event)"
          if ov else "calibration: no overhead sample (tracing off?)")
    for name, r in fl["workflows"].items():
        print(f"  {name:10s} faults: FT {r['ft_completed_fraction']:.0%} "
              f"complete @ {r['inflation']:.2f}x makespan "
              f"(static {r['static_completed_fraction']:.0%}; "
              f"{r['failures']} failures/{r['retries']} retries/"
              f"{r['lost_nodes']} lost nodes/{r['censored']} censored)")
    print(f"fault arm: {fl['ft_complete']}/{fl['n_workflows']} complete, "
          f"max inflation {fl['max_inflation']:.2f}x "
          f"(bound {fl['inflation_bound']}x), static strands on "
          f"{fl['static_strands']}/{fl['n_workflows']}")
    for name, r in loc["workflows"].items():
        print(f"  {name:10s} locality: realized makespan blind "
              f"{r['makespan_blind']:.0f} -> aware {r['makespan_aware']:.0f} "
              f"({'win' if r['win'] else 'no win'}; cross-rack edges "
              f"{r['cross_rack_edges_blind']} -> "
              f"{r['cross_rack_edges_aware']})")
    ls = loc["scale"]
    print(f"locality: aware wins {loc['locality_wins']}/"
          f"{loc['n_workflows']}  10k smoke: {ls['n_tasks']} tasks "
          f"({ls['n_edges']} edges) scheduled comm-aware in "
          f"{ls['schedule_s']:.2f}s (bound {ls['latency_bound_s']}s)")
    for p in sc["points"]:
        print(f"  scale ({p['t']:5d}x{p['n']:3d} = {p['cells']:7d} cells) "
              f"tick {p['legacy_tick_s']*1e3:.2f}ms legacy -> "
              f"{p['fused_tick_s']*1e3:.2f}ms fused "
              f"({p['speedup']:.1f}x)")
    for p in sc["fleet"]:
        print(f"  fleet W={p['w']:2d} ({p['cells']:7d} cells, "
              f"{p['devices']} device(s), "
              f"{'sharded' if p['sharded'] else 'unsharded'}) "
              f"tick {p['tick_s']*1e3:.2f}ms = "
              f"{p['cells_per_s']/1e6:.1f}M cells/s")
    print(f"scale gate: {sc['gate_speedup']:.1f}x fused-over-legacy at "
          f">= {sc['gate_cells']} cells (need >= {sc['min_speedup']}x)")
    print(f"wrote {OUT}")
    return [("bench_online.update_throughput", thr["update_s"] * 1e6,
             f"speedup={thr['update_speedup_vs_refit']:.0f}x"),
            ("bench_online.equivalence", 0.0,
             f"rel={eq['max_rel_diff_mean']:.1e};"
             f"gate={eq['pearson_gate_equal']}"),
            ("bench_online.mpe_wins", 0.0,
             f"{wf['online_mpe_wins']}/{wf['n_workflows']}"),
            ("bench_online.bias_mpe_wins", 0.0,
             f"{wf['bias_mpe_wins']}/{wf['n_workflows']}"),
            ("bench_online.risk_makespan_wins", 0.0,
             f"{wf['risk_makespan_wins']}/{wf['n_workflows']}"),
            ("bench_online.calibration_in_band", 0.0,
             f"{wf['calibration_in_band']}/{wf['n_workflows']}"),
            ("bench_online.fault_completion", 0.0,
             f"{fl['ft_complete']}/{fl['n_workflows']};"
             f"inflation={fl['max_inflation']:.2f}x"),
            ("bench_online.locality_wins", 0.0,
             f"{loc['locality_wins']}/{loc['n_workflows']};"
             f"10k={ls['schedule_s']:.2f}s"),
            ("bench_online.scale_speedup", sc["gate_speedup"],
             f"{sc['gate_speedup']:.1f}x@>={sc['gate_cells']}cells")]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes (CI smoke)")
    ap.add_argument("--gate", action="store_true",
                    help="small throughput shapes but FULL-size workflow "
                         "arms — the CI perf gate asserts the online and "
                         "bias MPE wins on these numbers")
    ap.add_argument("--scale-smoke", action="store_true",
                    help="tiny (W=4, T=64, N=8) scale arm only, no "
                         "BENCH_online.json write — the CI multi-device "
                         "sharding smoke")
    ap.add_argument("--locality-smoke", action="store_true",
                    help="schedule the >= 10k-task synthetic DAG "
                         "comm-aware and enforce the latency bound; no "
                         "BENCH_online.json write — the CI scheduling "
                         "smoke")
    a = ap.parse_args()
    if a.locality_smoke:
        ls = locality_scale()
        ok = (ls["n_tasks"] >= ls["min_tasks"]
              and ls["schedule_s"] <= ls["latency_bound_s"])
        print(f"locality smoke: {ls['n_tasks']} tasks ({ls['n_edges']} "
              f"edges) on {ls['n_nodes']} nodes scheduled comm-aware in "
              f"{ls['schedule_s']:.2f}s (need >= {ls['min_tasks']} tasks "
              f"within {ls['latency_bound_s']}s) -> "
              f"{'ok' if ok else 'FAIL'}")
        raise SystemExit(0 if ok else 1)
    if a.scale_smoke:
        sc = bench_scale(points=[(64, 8)], fleet_ws=[4],
                         fleet_t=64, fleet_n=8)
        p = sc["points"][0]
        print(f"scale smoke ({p['t']}x{p['n']}): legacy "
              f"{p['legacy_tick_s']*1e3:.2f}ms fused "
              f"{p['fused_tick_s']*1e3:.2f}ms ({p['speedup']:.1f}x)")
        f = sc["fleet"][0]
        print(f"fleet smoke W={f['w']} on {f['devices']} device(s) "
              f"({'sharded' if f['sharded'] else 'unsharded'}): "
              f"{f['tick_s']*1e3:.2f}ms/tick")
    elif a.quick:
        run(n_tasks=64, n_samples=2, nodes_per_type=1,
            scale_points=[(128, 16)], fleet_ws=[2])
    elif a.gate:
        run(n_tasks=64, n_samples=8, nodes_per_type=2,
            scale_points=SCALE_POINTS_GATE, fleet_ws=[4])
    else:
        run()
