"""Paper Fig. 4: impact of the number and cumulative size of downsampled
partitions on prediction error (eager-1 tasks).

We enumerate random subsets of the 10 geometric partitions (the paper uses
all 1013 combinations; we sample 200 per task for benchmark runtime) and
report how error varies with cumulative-size fraction, reproducing the
paper's observation: combinations below ~10% cumulative size are noisy;
above it, partition count barely matters (>=3 partitions).
"""
from __future__ import annotations

import itertools
import time

import numpy as np

from repro.core import LotaruEstimator, get_node, profile_cluster, profile_node, target_nodes
from repro.core.blr import fit_task
from repro.core.downsample import partition_sizes
from repro.sched.simulator import ClusterSimulator
from repro.sched.workflows import INPUTS, WORKFLOWS


REP_TASKS = ["bwa", "fastqc", "markduplicates", "genotyping_hc",
             "samtools_f_a_f", "bcftools_stats"]


def run(n_subsets: int = 200, seed: int = 0) -> list[tuple]:
    t0 = time.perf_counter()
    sim = ClusterSimulator(seed=seed)
    truth = ClusterSimulator(seed=seed + 1000)
    local = get_node("local-cpu")
    size = INPUTS[("eager", 1)]
    sizes = np.array(partition_sizes(size, 10))
    tasks = {t.name: t for t in WORKFLOWS["eager"]}
    rng = np.random.default_rng(seed)

    all_idx = list(range(10))
    subsets = []
    for k in range(2, 11):
        combos = list(itertools.combinations(all_idx, k))
        rng.shuffle(combos)
        subsets.extend(combos[:max(2, n_subsets // 9)])

    print(f"{'task':18s} {'<10% cum':>12s} {'>=10% cum':>12s} {'n<':>4s} {'n>':>4s}")
    rows = []
    for name in REP_TASKS:
        t = tasks[name]
        runtimes = np.array([sim.run_task(t, local, s) for s in sizes])
        actual = truth.run_task(t, local, size)
        lo, hi = [], []
        for sub in subsets:
            idx = list(sub)
            model = fit_task(sizes[idx], runtimes[idx])
            pred = float(np.asarray(model.predict(size)[0]))
            err = abs(pred - actual) / actual
            frac = sizes[idx].sum() / size
            (hi if frac >= 0.10 else lo).append(err)
        print(f"{name:18s} {100*np.median(lo):11.2f}% {100*np.median(hi):11.2f}%"
              f" {len(lo):4d} {len(hi):4d}")
        rows.append((f"fig4.downsampling.{name}",
                     (time.perf_counter() - t0) * 1e6 / len(REP_TASKS),
                     f"median_err_lowcum={100*np.median(lo):.2f}%"
                     f";highcum={100*np.median(hi):.2f}%"))
    return rows
