"""Paper Fig. 6: prediction errors on a homogeneous cluster (target = the
local machine type; no factor adjustment needed)."""
from __future__ import annotations

from repro.sched.evaluation import run_evaluation
from repro.sched.workflows import INPUTS

from .common import timed


def run() -> list[tuple]:
    res, us = timed(run_evaluation, seed=0, heterogeneous=False)
    rows = []
    print(f"{'workflow':14s} " + " ".join(f"{a:>9s}" for a in
                                          ("lotaru", "naive", "online_m", "online_p")))
    for (wf, ds) in INPUTS:
        key = f"{wf}-{ds}"
        vals = [100 * res.mpe(a, workflow=key) for a in
                ("lotaru", "naive", "online_m", "online_p")]
        print(f"{key:14s} " + " ".join(f"{v:8.2f}%" for v in vals))
    overall = {a: 100 * res.mpe(a) for a in ("lotaru", "naive", "online_m",
                                             "online_p")}
    print("overall        " + " ".join(f"{overall[a]:8.2f}%" for a in
                                       ("lotaru", "naive", "online_m", "online_p")))
    rows.append(("fig6.homogeneous_mpe", us,
                 f"lotaru={overall['lotaru']:.2f}%;best_baseline="
                 f"{min(overall['naive'], overall['online_m'], overall['online_p']):.2f}%"
                 f";paper=5.70%vs10.34%"))
    return rows
