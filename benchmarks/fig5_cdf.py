"""Paper Fig. 5: cumulative distribution of prediction errors for the four
approaches (eager-1 and atacseq-1)."""
from __future__ import annotations

import numpy as np

from repro.sched.evaluation import run_evaluation

from .common import timed


def run() -> list[tuple]:
    res, us = timed(run_evaluation, seed=0, heterogeneous=False)
    rows = []
    for wf in ("eager-1", "atacseq-1"):
        print(f"-- {wf}: error CDF (fraction of tasks with err <= x)")
        print(f"{'x':>6s} " + " ".join(f"{a:>9s}" for a in
                                       ("lotaru", "naive", "online_m", "online_p")))
        for x in (0.05, 0.10, 0.20, 0.50, 1.00):
            vals = []
            for a in ("lotaru", "naive", "online_m", "online_p"):
                errs = res.all_errors(a, workflow=wf)
                vals.append(float(np.mean(errs <= x)))
            print(f"{x:6.2f} " + " ".join(f"{v:9.2f}" for v in vals))
        e_l = res.all_errors("lotaru", workflow=wf)
        e_p = res.all_errors("online_p", workflow=wf)
        rows.append((f"fig5.cdf.{wf}", us / 2,
                     f"p50_lotaru={100*np.median(e_l):.2f}%"
                     f";p50_online_p={100*np.median(e_p):.2f}%"))
    return rows
