"""Beyond-paper: calibration of Lotaru's Bayesian uncertainty.

The paper's key selling point over frequentist baselines is the predictive
uncertainty handed to schedulers — but it never evaluates whether those
intervals are *calibrated*.  We do: for every (task, node, dataset) pair,
compute the central predictive interval at several confidence levels and
measure the empirical coverage of the actual runtimes, plus the
sharpness (median relative half-width).

Well-calibrated: empirical coverage ~= nominal.  Over-confident (< nominal)
intervals would make straggler envelopes fire on healthy nodes;
under-confident ones would mask real stragglers.
"""
from __future__ import annotations

import time

import numpy as np
from scipy import stats as sstats

from repro.core import (LotaruEstimator, get_node, profile_cluster,
                        profile_node, target_nodes)
from repro.sched.simulator import ClusterSimulator
from repro.sched.workflows import INPUTS, WORKFLOWS

LEVELS = (0.5, 0.8, 0.95)


def run(n_draws: int = 5) -> list[tuple]:
    t0 = time.perf_counter()
    sim = ClusterSimulator(seed=0)
    truth = ClusterSimulator(seed=3000)
    local = get_node("local-cpu")
    local_bench = profile_node(local, np.random.default_rng(7))
    tbenches = profile_cluster(target_nodes(), seed=13)

    cover = {lv: [] for lv in LEVELS}
    widths = []
    for (wf_name, ds), size in INPUTS.items():
        tasks = WORKFLOWS[wf_name]
        by_name = {t.name: t for t in tasks}
        est = LotaruEstimator(local_bench, tbenches)
        est.fit_tasks([t.name for t in tasks], size,
                      lambda n, s, cf: sim.run_task(by_name[n], local, s,
                                                    cpu_factor=cf))
        # one batched call per workflow for the (task x node) matrix, then
        # vectorised Student-t quantiles — no per-(task, node, draw) ppf
        node_types = list(target_nodes())
        task_idx = {n: i for i, n in enumerate(est.task_names())}
        mean_mat, std_mat = est.predict_matrix([n.name for n in node_types],
                                               size)
        dof = np.array([(float(est.tasks[t.name].model.post.dof)
                         if est.tasks[t.name].model.correlated else 6.0)
                        for t in tasks])
        means, stds, dofs, actuals = [], [], [], []
        for t in tasks:                    # same truth-sim call order as the
            ti = task_idx[t.name]          # scalar path (RNG stream intact)
            for nj, node in enumerate(node_types):
                mean, std = mean_mat[ti, nj], std_mat[ti, nj]
                if std <= 0:
                    continue
                means.append(mean)
                stds.append(std)
                dofs.append(dof[ti])
                actuals.append([truth.run_task(t, node, size)
                                for _ in range(n_draws)])
        if not means:
            continue
        means = np.array(means)            # (P,)
        stds = np.array(stds)
        dofs = np.array(dofs)
        A = np.array(actuals)              # (P, draws)
        widths.extend(stds / np.maximum(means, 1e-9))
        for lv in LEVELS:
            tq = sstats.t.ppf(0.5 + lv / 2.0, df=dofs)          # (P,)
            lo = (means - tq * stds)[:, None]
            hi = (means + tq * stds)[:, None]
            cover[lv].extend(((lo <= A) & (A <= hi)).reshape(-1))

    rows = []
    print(f"{'nominal':>8s} {'empirical':>10s} {'n':>6s}")
    for lv in LEVELS:
        emp = float(np.mean(cover[lv]))
        print(f"{lv:8.2f} {emp:10.3f} {len(cover[lv]):6d}")
        rows.append((f"calibration.cov{int(lv*100)}",
                     (time.perf_counter() - t0) * 1e6 / len(LEVELS),
                     f"nominal={lv};empirical={emp:.3f}"))
    print(f"sharpness: median rel half-width(1sigma) = "
          f"{100*np.median(widths):.1f}%")
    return rows
