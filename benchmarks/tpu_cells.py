"""Beyond-paper: LotaruML over the (arch x shape) dry-run cells.

Tasks = compiled workload cells; input size = token count; local runs =
the developer CPU node; adjustment = three-term roofline factor.  MPE of
step-time predictions across heterogeneous TPU node types, vs the same
baselines (which are node-unaware).
"""
from __future__ import annotations

import time
from pathlib import Path

import numpy as np

from repro.core import (BASELINES, LotaruML, get_node, profile_cluster,
                        profile_node, target_nodes)
from repro.core.downsample import partition_sizes
from repro.sched.simulator import ClusterSimulator, load_dryrun_cells

ART = Path(__file__).resolve().parents[1] / "experiments" / "artifacts" / "dryrun"


def run(mesh: str = "pod16x16") -> list[tuple]:
    t0 = time.perf_counter()
    cells = [c for c in load_dryrun_cells(ART) if c["mesh"] == mesh]
    if not cells:
        print("no dry-run artifacts found — run repro.launch.dryrun first")
        return [("tpu_cells.skipped", 0.0, "no artifacts")]
    sim = ClusterSimulator(seed=0)
    truth = ClusterSimulator(seed=1000)
    local = get_node("local-cpu")
    local_bench = profile_node(local, np.random.default_rng(7))
    tbenches = profile_cluster(target_nodes(), seed=13)
    est = LotaruML(local_bench, tbenches)

    for c in cells:
        est.fit_cell(
            c, lambda cell, frac: sim.run_cell(cell, local, frac),
            run_local_throttled=lambda cell, frac: sim.run_cell(
                cell, local, frac, cpu_factor=0.8))

    base_fits = {}
    for c in cells:
        name = f"{c['arch']}__{c['shape']}"
        fracs = np.array(partition_sizes(1.0, 6))
        tokens = fracs * c["roofline"]["step_tokens"]
        runtimes = np.array([sim.run_cell(c, local, f) for f in fracs])
        base_fits[name] = {b: cls().fit(tokens, runtimes)
                           for b, cls in BASELINES.items()}

    errs: dict[str, list] = {a: [] for a in
                             ["lotaru_ml", "lotaru_scalar", "naive",
                              "online_m", "online_p"]}
    for c in cells:
        name = f"{c['arch']}__{c['shape']}"
        for node in target_nodes():
            actual = truth.run_cell(c, node)
            pred, _ = est.predict(name, node.name)
            errs["lotaru_ml"].append(abs(pred - actual) / actual)
            ps, _ = est.predict_scalar(name, node.name)
            errs["lotaru_scalar"].append(abs(ps - actual) / actual)
            for b in ("naive", "online_m", "online_p"):
                p = float(np.asarray(
                    base_fits[name][b].predict(
                        c["roofline"]["step_tokens"])).reshape(-1)[0])
                errs[b].append(abs(p - actual) / actual)

    print(f"{len(cells)} cells x {len(target_nodes())} node types ({mesh})")
    out = []
    for a, es in errs.items():
        print(f"  {a:10s}: MPE {100*np.median(es):7.2f}%  p90 {100*np.percentile(es,90):7.2f}%")
    us = (time.perf_counter() - t0) * 1e6
    out.append(("tpu_cells.heterogeneous_mpe", us,
                f"lotaru_ml={100*np.median(errs['lotaru_ml']):.2f}%"
                f";online_p={100*np.median(errs['online_p']):.2f}%"
                f";cells={len(cells)}"))
    return out
