"""§Roofline: the three-term table over all dry-run cells (v5e constants)."""
from __future__ import annotations

import time
from pathlib import Path

from repro.sched.simulator import load_dryrun_cells

ART = Path(__file__).resolve().parents[1] / "experiments" / "artifacts" / "dryrun"


def run() -> list[tuple]:
    t0 = time.perf_counter()
    cells = load_dryrun_cells(ART)
    if not cells:
        print("no dry-run artifacts — run repro.launch.dryrun first")
        return [("roofline.skipped", 0.0, "no artifacts")]
    print(f"{'cell':60s} {'comp_s':>9s} {'mem_s':>9s} {'coll_s':>9s} "
          f"{'bound':>10s} {'useful':>7s} {'rf':>6s}")
    worst = None
    for c in cells:
        r = c["roofline"]
        name = f"{c['arch']}.{c['shape']}.{c['mesh']}"
        print(f"{name:60s} {r['compute_s']:9.3e} {r['memory_s']:9.3e} "
              f"{r['collective_s']:9.3e} {r['bound']:>10s} "
              f"{r['useful_flop_fraction']:7.2f} {r['roofline_fraction']:6.3f}")
        if c["shape"] != "decode_32k" and c["shape"] != "long_500k":
            if worst is None or r["roofline_fraction"] < worst[1]:
                worst = (name, r["roofline_fraction"])
    us = (time.perf_counter() - t0) * 1e6
    return [("roofline.table", us,
             f"cells={len(cells)};worst_nondec={worst[0] if worst else 'n/a'}"
             f"@{worst[1]:.3f}" if worst else f"cells={len(cells)}")]
