"""Render a structured execution trace (JSONL, written by ``EventLog``)
as a human-readable report: event counters, predictive-interval
calibration (coverage / PIT / sharpness), per-phase tick-latency
breakdown with the first-call XLA compile split out, and the
fault/retry narrative.

Usage::

    python scripts/report_trace.py traces/chipseq.jsonl
    python scripts/report_trace.py traces/*.jsonl --json report.json

With ``--json`` the machine-readable ``report_dict`` of every trace is
additionally written to the given path (keyed by trace filename) — the
artifact CI uploads next to the JSONL traces.
"""
import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.obs import load_jsonl, render_report, report_dict  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("traces", nargs="+", type=Path,
                    help="EventLog JSONL trace file(s)")
    ap.add_argument("--json", type=Path, default=None,
                    help="also write machine-readable report_dict(s) here")
    ap.add_argument("--min-obs", type=int, default=20,
                    help="calibration warm-up: observations excluded "
                         "before coverage/PIT are scored (default 20)")
    args = ap.parse_args(argv)

    reports = {}
    for path in args.traces:
        events = load_jsonl(path)
        if len(args.traces) > 1:
            print(f"\n### {path} " + "#" * max(0, 58 - len(str(path))))
        print(render_report(events, min_obs=args.min_obs))
        reports[path.name] = report_dict(events, min_obs=args.min_obs)

    if args.json is not None:
        args.json.write_text(json.dumps(reports, indent=2, default=float))
        print(f"\nwrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
