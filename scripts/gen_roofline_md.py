"""Generate the §Roofline markdown table from dry-run artifacts."""
import json
import sys
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "experiments" / "artifacts" / "dryrun"


def fmt(x, digits=3):
    if x == 0:
        return "0"
    if x < 1e-3 or x >= 1e4:
        return f"{x:.2e}"
    return f"{x:.{digits}f}"


def main(mesh_filter=None):
    recs = [json.loads(p.read_text()) for p in sorted(ART.glob("*.json"))]
    print("| cell | mesh | bound | compute_s | memory_s | collective_s | "
          "useful_flops | roofline_frac | HBM/dev | fits 16GB | note |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9), r["mesh"]))
    for r in recs:
        cell = f"{r['arch']} × {r['shape']}"
        if r["status"] == "skip":
            print(f"| {cell} | {r['mesh']} | — | — | — | — | — | — | — | — | "
                  f"skip: {r['reason'].split(':')[0]} |")
            continue
        if r["status"] != "ok":
            print(f"| {cell} | {r['mesh']} | ERROR | | | | | | | | |")
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        rf = r["roofline"]
        mem = r["memory"]
        print(f"| {cell} | {r['mesh']} | **{rf['bound']}** | "
              f"{fmt(rf['compute_s'])} | {fmt(rf['memory_s'])} | "
              f"{fmt(rf['collective_s'])} | {rf['useful_flop_fraction']:.2f} | "
              f"{rf['roofline_fraction']:.3f} | "
              f"{mem['hbm_estimate_bytes']/1e9:.1f}GB | "
              f"{'yes' if mem['fits_16gb'] else 'no'} | |")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else None)
