#!/usr/bin/env python
"""Run the repo's static-analysis suite (repro.analysis.lint).

CI gate: exits non-zero when any diagnostic survives suppression.

    python scripts/lint_repro.py                  # src/ benchmarks/ scripts/
    python scripts/lint_repro.py src/repro/core   # a subtree
    python scripts/lint_repro.py --select RA003,RA004
    python scripts/lint_repro.py --list-rules

Output is ``path:line:col: RULE message`` (clickable in most editors).
When ``$GITHUB_STEP_SUMMARY`` is set, a markdown table naming each
rule + file:line is appended there so CI failures are readable from
the job summary without opening the log.
"""
import argparse
import os
import sys
from collections import Counter
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis.lint import RULE_DOCS, registered_passes, run_paths  # noqa: E402

DEFAULT_PATHS = ("src", "benchmarks", "scripts")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to lint (default: {DEFAULT_PATHS})")
    ap.add_argument("--select", default=None, metavar="RULES",
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        registered_passes()          # populate RULE_DOCS
        for rule in sorted(RULE_DOCS):
            print(f"{rule}  {RULE_DOCS[rule]}")
        return 0

    select = [r.strip().upper() for r in args.select.split(",")] \
        if args.select else None
    paths = args.paths or [str(ROOT / p) for p in DEFAULT_PATHS]
    diags, project = run_paths(paths, select=select)

    for d in diags:
        try:
            shown = Path(d.path).resolve().relative_to(ROOT)
        except ValueError:
            shown = d.path
        print(f"{shown}:{d.line}:{d.col}: {d.rule} {d.message}")

    n_files = len(project.files)
    if diags:
        counts = ", ".join(f"{r} x{n}" for r, n in
                           sorted(Counter(d.rule for d in diags).items()))
        print(f"\n{len(diags)} finding(s) in {n_files} file(s): {counts}",
              file=sys.stderr)
        _github_summary(diags)
        return 1
    print(f"lint_repro: {n_files} files clean", file=sys.stderr)
    return 0


def _github_summary(diags) -> None:
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if not summary:
        return
    with open(summary, "a") as fh:
        fh.write("## lint_repro findings\n\n| rule | location | message |\n"
                 "|---|---|---|\n")
        for d in diags:
            msg = d.message.replace("|", "\\|")
            fh.write(f"| {d.rule} | `{d.path}:{d.line}` | {msg} |\n")


if __name__ == "__main__":
    raise SystemExit(main())
