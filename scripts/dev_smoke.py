"""Dev loop: run every smoke config through loss / prefill / decode."""
import sys
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import list_archs, smoke_config
from repro.models import build_model, AxisRules

rules = AxisRules(fsdp_axes=(), dp_axes=())
B, T = 2, 24

want = sys.argv[1:] or list_archs()
for arch in want:
    cfg = smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        nv = 8
        batch["vision_embeds"] = jnp.ones((B, nv, cfg.d_model), jnp.bfloat16) * 0.1
        pos = jnp.broadcast_to(jnp.arange(T + nv, dtype=jnp.int32)[None, :, None],
                               (B, T + nv, 3))
        batch["positions"] = pos
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(key, (B, 16, cfg.d_model)) * 0.1

    loss, metrics = jax.jit(lambda p, b: model.loss(p, b, rules))(params, batch)
    assert np.isfinite(float(loss)), (arch, loss)

    # prefill + decode
    caches = model.init_caches(B, max_len=T + 8, cross_len=16)
    logits, caches = jax.jit(lambda p, b, c: model.prefill(p, b, c, rules))(
        params, batch, caches)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch
    step_tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    dbatch = {"tokens": step_tok}
    if cfg.family == "vlm":
        dbatch["positions"] = jnp.full((B, 1, 3), T + 8, jnp.int32)
    logits2, caches = jax.jit(
        lambda p, b, c, i: model.decode(p, b, c, i, rules))(
        params, dbatch, caches, jnp.asarray(T, jnp.int32))
    assert np.all(np.isfinite(np.asarray(logits2, np.float32))), arch
    print(f"OK {arch:28s} loss={float(loss):.3f} params={n_params:,}")
print("ALL OK")
