"""Dump the top collectives (trip-aware) of one dry-run cell, attributed by
op_name metadata — the §Perf profiling tool.

  PYTHONPATH=src python scripts/probe_collectives.py qwen2-7b train_4k single
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import collections
import re
import sys

import jax
import jax.numpy as jnp

from repro.analysis.hlo_stats import _DEF_RE, _shape_bytes, _split_blocks, analyze_hlo
from repro.configs import get_config
from repro.launch.dryrun import ARCH_DIST, _moe_groups_for
from repro.launch.mesh import make_production_mesh, make_rules
from repro.launch.shapes import SHAPES, input_specs
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models import build_model
from repro.models.common import tree_defs_to_abstract
from repro.optim import AdamWConfig, state_defs
from jax.sharding import NamedSharding, PartitionSpec as P


def compile_cell(arch, shape_name, multi_pod):
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    dist = ARCH_DIST.get(arch, {})
    mesh = make_production_mesh(multi_pod=multi_pod)
    overrides = dict(dist.get("overrides", {}))
    if cfg.n_kv_heads % int(mesh.shape["model"]) != 0:
        overrides.setdefault("kv_seq", "model")
    rules = make_rules(mesh, fsdp_over_pod=dist.get("fsdp_over_pod", False),
                       overrides=overrides)
    cfg = cfg.with_(moe_groups=_moe_groups_for(cfg, mesh, rules))
    if dist.get("param_dtype") == "bf16":
        cfg = cfg.with_(param_dtype=jnp.bfloat16)
    model = build_model(cfg)
    opt = AdamWConfig(state_dtype=dist.get("opt_state_dtype", "fp32"),
                      master_fp32=dist.get("master_fp32", False))
    with mesh:
        pa = model.abstract_params(mesh, rules)
        batch = input_specs(cfg, shape, mesh, rules)
        if shape.kind == "train":
            oa = tree_defs_to_abstract(state_defs(model.param_defs, opt),
                                       mesh, rules)
            gd = dist.get("grad_dtype")
            step = make_train_step(model, rules, opt,
                                   microbatches=dist.get("microbatches", 1),
                                   grad_dtype=jnp.bfloat16 if gd == "bf16" else None)
            c = jax.jit(step, donate_argnums=(0, 1)).lower(pa, oa, batch).compile()
        elif shape.kind == "prefill":
            caches = model.abstract_caches(mesh, rules, shape.global_batch,
                                           max_len=shape.seq, cross_len=shape.seq)
            c = jax.jit(make_prefill_step(model, rules),
                        donate_argnums=(2,)).lower(pa, batch, caches).compile()
        else:
            caches = model.abstract_caches(mesh, rules, shape.global_batch,
                                           max_len=shape.seq, cross_len=shape.seq)
            idx = jax.ShapeDtypeStruct((), jnp.int32,
                                       sharding=NamedSharding(mesh, P()))
            c = jax.jit(make_decode_step(model, rules),
                        donate_argnums=(2,)).lower(pa, batch, caches, idx).compile()
    return c, mesh


def main():
    arch, shape_name, mesh_kind = sys.argv[1], sys.argv[2], sys.argv[3]
    top = int(sys.argv[4]) if len(sys.argv) > 4 else 20
    c, mesh = compile_cell(arch, shape_name, mesh_kind == "multi")
    txt = c.as_text()
    blocks = _split_blocks(txt)
    stats = analyze_hlo(txt, default_group=mesh.size)
    print(f"flops/dev {stats.flops:.3e}  hbm_adj {stats.hbm_bytes_kernel_adj/1e12:.2f}TB  "
          f"coll {stats.collective_bytes/1e9:.1f}GB  "
          f"{stats.collective_bytes_by_op}")

    # trip-aware multipliers: re-derive by re-running the fixpoint
    from repro.analysis import hlo_stats as H
    # approximate: every while body named wide.* executes its trip count;
    # use static counts weighted by known trip counts from the while lines
    trips = {}
    for bname, lines in blocks.items():
        for line in lines:
            if " while(" in line:
                b = H._BODY_RE.search(line)
                t = H._TRIP_RE.search(line)
                if b and t:
                    trips[b.group(1)] = int(t.group(1))
    agg = collections.Counter()
    for bname, lines in blocks.items():
        mult = trips.get(bname, 1 if bname.startswith("main") else 0)
        if mult == 0 and not bname.startswith("main"):
            # nested: approximate with product if parent known
            mult = trips.get(bname, 0)
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            _, shp, opc = m.groups()
            if opc in ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute"):
                mm = re.search(r'op_name="([^"]*)"', line)
                opname = re.sub(r"\d+", "", mm.group(1))[:80] if mm else "?"
                agg[(opc, shp[:44], opname)] += max(mult, 1)
    rows = sorted(agg.items(), key=lambda kv: -_shape_bytes(kv[0][1]) * kv[1])
    for (opc, shp, opname), n in rows[:top]:
        print(f"{n:5d}x {opc:12s} {_shape_bytes(shp)/1e6:9.1f}MB {shp:44s} {opname[:78]}")


if __name__ == "__main__":
    main()
