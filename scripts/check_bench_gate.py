"""CI perf gate over BENCH_online.json (written by bench_online --gate).

Fails the build when either online-estimation win regresses:

* online-vs-static final MPE must win on ALL workflows (PR 2 invariant);
* bias-corrected online must beat the bias-free (PR 2) online final MPE
  on >= 3 of the 5 workflows (PR 3 invariant).
"""
import json
import sys
from pathlib import Path

BENCH = Path(__file__).resolve().parents[1] / "BENCH_online.json"


def main() -> int:
    e = json.loads(BENCH.read_text())["execution"]
    n = e["n_workflows"]
    ok = True
    if e["online_mpe_wins"] != n:
        print(f"FAIL online-vs-static MPE wins {e['online_mpe_wins']}/{n} "
              "(expected all)")
        ok = False
    if e["bias_mpe_wins"] < 3:
        print(f"FAIL bias-vs-PR2 MPE wins {e['bias_mpe_wins']}/{n} "
              "(expected >= 3)")
        ok = False
    print(f"online {e['online_mpe_wins']}/{n}, bias {e['bias_mpe_wins']}/{n}"
          + ("" if ok else " -- GATE FAILED"))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
