"""CI perf gate over BENCH_online.json (written by bench_online --gate).

Fails the build when an online-estimation win regresses, and names the
arm and the specific workflows that regressed (a bare pass/fail count is
useless when bisecting which workflow moved):

* online-vs-static final MPE must win on ALL workflows (PR 2 invariant);
* bias-corrected online must beat the bias-free (PR 2) online final MPE
  on >= 3 of the 5 workflows (PR 3 invariant);
* the risk-aware arm (bias + EB sigma_r + risk_k HEFT + tail-mass
  speculation) must win or tie the bias arm's final makespan on >= 3 of
  the 5 workflows (PR 4 invariant; ties count — risk pricing that leaves
  the argmin placement unchanged is not a regression);
* under the default crash sweep (two nodes dying mid-run + ~5% attempt
  failures) the fault-tolerant arm must complete 100% of EVERY workflow
  with makespan inflation within the committed bound, and the static
  baseline must strand work somewhere (otherwise the scenario has gone
  soft and proves nothing) — PR 5 invariant;
* the predictive intervals must be *calibrated*: post-warm-up empirical
  coverage of the 90% interval implied by the risk-pricing σ in
  [0.80, 0.98] on >= 4/5 workflows (PR 6 invariant — both over- and
  under-coverage corrupt risk_k pricing and speculation admission);
* the fused tick must beat the legacy observe → update → re-predict
  sequence by the committed factor at every (T, N) point with >= 100k
  estimate-matrix cells (PR 9 invariant — the array-native engine
  exists to make tick cost independent of Python dispatch, and a scale
  section that has gone missing means the arm silently stopped running);
* data-aware HEFT must earn its transfer term: on the two-rack
  scatter/gather scenario the comm-aware plan's REALIZED makespan (both
  plans replayed under the true transfer prices) must beat the
  comm-blind plan on >= 3/5 workflows and never lose by more than 2%
  (greedy-EFT myopia tolerance), and the >= 10k-task
  synthetic-DAG comm-aware schedule must come in under the committed
  latency bound (PR 10 invariant — a vanished locality section means
  the arm silently stopped running).
"""
import json
import sys
from pathlib import Path

BENCH = Path(__file__).resolve().parents[1] / "BENCH_online.json"

#: gate name -> (per-workflow pass predicate, minimum wins required as a
#: fraction of n (1.0 = all), key of the bench's own summary count).
#: Each predicate sees one workflow's record; the summary key is
#: cross-checked so the gate and bench_online cannot silently disagree
#: about what counts as a win.
GATES = {
    "online-vs-static MPE": (
        lambda r: r["mpe_online"] < r["mpe_static"], 1.0,
        "online_mpe_wins"),
    "bias-vs-PR2 MPE": (
        lambda r: r["mpe_online"] < r["mpe_online_nobias"], 0.6,
        "bias_mpe_wins"),
    "risk-vs-bias makespan (win-or-tie)": (
        lambda r: r["makespan_online_risk"]
        <= r["makespan_online"] * (1 + 1e-9), 0.6,
        "risk_makespan_wins"),
    # PR 6 invariant: once >= 20 observations have streamed in, the
    # empirical coverage of the 90% predictive interval implied by the
    # sigma that risk_k pricing consumes (mean ± 1.645σ) must land in
    # [0.80, 0.98] on >= 4 of the 5 workflows — a σ nobody checks is a σ
    # nobody should price risk with.  The upper bound matters as much as
    # the lower: overcoverage means the intervals are too wide and the
    # risk premium is systematically overpaid.
    "calibration: 90% interval coverage in band": (
        lambda r: r["calibration_n_obs"] >= 20
        and 0.80 <= r["coverage90_z"] <= 0.98, 0.8,
        "calibration_in_band"),
}


#: fault-section gates: name -> (predicate over one workflow record given
#: the section, min fraction, summary key).  Separate table because the
#: records live under ``faults``, not ``execution``.
FAULT_GATES = {
    "fault-arm 100% completion": (
        lambda r, f: r["ft_completed_fraction"] >= 1.0, 1.0,
        "ft_complete"),
    "fault-arm makespan inflation": (
        lambda r, f: r["inflation"] <= f["inflation_bound"], 1.0,
        None),
    "static baseline strands work": (
        lambda r, f: r["static_completed_fraction"] < 1.0, 0.6,
        "static_strands"),
}


def _check(name, pred, frac, summary_key, wfs, section, detail_fn):
    n = len(wfs)
    need = max(1, int(round(frac * n)))
    losers = [wf for wf, r in wfs.items() if not pred(r)]
    wins = n - len(losers)
    status = "ok  " if wins >= need else "FAIL"
    print(f"{status} {name}: {wins}/{n} (need >= {need})")
    ok = wins >= need
    if summary_key and summary_key in section and \
            section[summary_key] != wins:
        print(f"FAIL {name}: gate recount {wins} != bench summary "
              f"{summary_key}={section[summary_key]} — the two win "
              "definitions have drifted apart")
        ok = False
    for wf in losers:
        marker = "regressed" if wins < need else "lost (within budget)"
        print(f"       {wf}: {marker} — {detail_fn(wfs[wf])}")
    return ok


def main() -> int:
    bench = json.loads(BENCH.read_text())
    e = bench["execution"]
    ok = True

    def exec_detail(r):
        return (f"static={r['mpe_static']:.3f} "
                f"PR2={r['mpe_online_nobias']:.3f} "
                f"bias={r['mpe_online']:.3f} "
                f"risk={r['mpe_online_risk']:.3f} | makespan "
                f"bias={r['makespan_online']:.0f} "
                f"risk={r['makespan_online_risk']:.0f} | "
                f"coverage90 z={r.get('coverage90_z', float('nan')):.3f} "
                f"t={r.get('coverage90', float('nan')):.3f} "
                f"(n={r.get('calibration_n_obs', 0)})")

    for name, (pred, frac, summary_key) in GATES.items():
        ok &= _check(name, pred, frac, summary_key, e["workflows"], e,
                     exec_detail)

    f = bench.get("faults")
    if f is None:
        print("FAIL fault section missing from BENCH_online.json — "
              "bench_online predates the fault arm or was truncated")
        ok = False
    else:
        def fault_detail(r):
            return (f"completed {r['ft_completed_fraction']:.0%} "
                    f"(static {r['static_completed_fraction']:.0%}) "
                    f"inflation {r['inflation']:.2f}x "
                    f"(bound {f['inflation_bound']}x) | "
                    f"{r['failures']} failures/{r['retries']} retries/"
                    f"{r['lost_nodes']} lost nodes")

        for name, (pred, frac, summary_key) in FAULT_GATES.items():
            ok &= _check(name, lambda r, p=pred: p(r, f), frac,
                         summary_key, f["workflows"], f, fault_detail)

    s = bench.get("scale")
    if s is None:
        print("FAIL scale section missing from BENCH_online.json — "
              "bench_online predates the fused-tick arm or was truncated")
        ok = False
    else:
        gate_pts = [p for p in s["points"]
                    if p["cells"] >= s["gate_cells"]]
        if not gate_pts:
            print(f"FAIL scale: no (T, N) point reaches the "
                  f"{s['gate_cells']}-cell gate size")
            ok = False
        for p in gate_pts:
            win = (p["fused_tick_s"] < p["legacy_tick_s"]
                   and p["speedup"] >= s["min_speedup"])
            status = "ok  " if win else "FAIL"
            print(f"{status} scale: fused tick at {p['t']}x{p['n']} "
                  f"({p['cells']} cells): {p['speedup']:.1f}x over legacy "
                  f"(need >= {s['min_speedup']}x; legacy "
                  f"{p['legacy_tick_s']*1e3:.2f}ms, fused "
                  f"{p['fused_tick_s']*1e3:.2f}ms)")
            ok &= win

    loc = bench.get("locality")
    if loc is None:
        print("FAIL locality section missing from BENCH_online.json — "
              "bench_online predates the data-aware arm or was truncated")
        ok = False
    else:
        def loc_detail(r):
            return (f"realized blind={r['makespan_blind']:.0f} "
                    f"aware={r['makespan_aware']:.0f} | cross-rack edges "
                    f"{r['cross_rack_edges_blind']} -> "
                    f"{r['cross_rack_edges_aware']}")

        ok &= _check("data-aware vs comm-blind realized makespan",
                     lambda r: r["makespan_aware"] < r["makespan_blind"],
                     0.6, "locality_wins", loc["workflows"], loc,
                     loc_detail)
        # never lose meaningfully: greedy EFT with a transfer term can
        # make myopic calls, but a > 2% realized regression means the
        # pricing is steering placement wrong, not just tying
        losses = [wf for wf, r in loc["workflows"].items()
                  if r["makespan_aware"] > r["makespan_blind"] * 1.02]
        if losses:
            print(f"FAIL data-aware arm loses > 2% to comm-blind on "
                  f"{', '.join(losses)} — the transfer term is "
                  "mispricing placement")
            ok = False
        else:
            print(f"ok   data-aware arm never loses > 2% "
                  f"({loc['n_workflows']} workflows)")
        ls = loc["scale"]
        big = (ls["n_tasks"] >= ls["min_tasks"]
               and ls["schedule_s"] <= ls["latency_bound_s"])
        status = "ok  " if big else "FAIL"
        print(f"{status} locality scale: {ls['n_tasks']} tasks / "
              f"{ls['n_edges']} edges comm-aware in "
              f"{ls['schedule_s']:.2f}s (need >= {ls['min_tasks']} "
              f"tasks within {ls['latency_bound_s']}s)")
        ok &= big

    if not ok:
        print("-- GATE FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
