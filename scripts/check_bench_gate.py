"""CI perf gate over BENCH_online.json (written by bench_online --gate).

Fails the build when an online-estimation win regresses, and names the
arm and the specific workflows that regressed (a bare pass/fail count is
useless when bisecting which workflow moved):

* online-vs-static final MPE must win on ALL workflows (PR 2 invariant);
* bias-corrected online must beat the bias-free (PR 2) online final MPE
  on >= 3 of the 5 workflows (PR 3 invariant);
* the risk-aware arm (bias + EB sigma_r + risk_k HEFT + tail-mass
  speculation) must win or tie the bias arm's final makespan on >= 3 of
  the 5 workflows (PR 4 invariant; ties count — risk pricing that leaves
  the argmin placement unchanged is not a regression).
"""
import json
import sys
from pathlib import Path

BENCH = Path(__file__).resolve().parents[1] / "BENCH_online.json"

#: gate name -> (per-workflow pass predicate, minimum wins required as a
#: fraction of n (1.0 = all), key of the bench's own summary count).
#: Each predicate sees one workflow's record; the summary key is
#: cross-checked so the gate and bench_online cannot silently disagree
#: about what counts as a win.
GATES = {
    "online-vs-static MPE": (
        lambda r: r["mpe_online"] < r["mpe_static"], 1.0,
        "online_mpe_wins"),
    "bias-vs-PR2 MPE": (
        lambda r: r["mpe_online"] < r["mpe_online_nobias"], 0.6,
        "bias_mpe_wins"),
    "risk-vs-bias makespan (win-or-tie)": (
        lambda r: r["makespan_online_risk"]
        <= r["makespan_online"] * (1 + 1e-9), 0.6,
        "risk_makespan_wins"),
}


def main() -> int:
    e = json.loads(BENCH.read_text())["execution"]
    wfs = e["workflows"]
    n = e["n_workflows"]
    ok = True
    for name, (pred, frac, summary_key) in GATES.items():
        need = max(1, int(round(frac * n)))
        losers = [wf for wf, r in wfs.items() if not pred(r)]
        wins = n - len(losers)
        status = "ok  " if wins >= need else "FAIL"
        print(f"{status} {name}: {wins}/{n} (need >= {need})")
        if wins < need:
            ok = False
        if summary_key in e and e[summary_key] != wins:
            print(f"FAIL {name}: gate recount {wins} != bench summary "
                  f"{summary_key}={e[summary_key]} — the two win "
                  "definitions have drifted apart")
            ok = False
        for wf in losers:
            r = wfs[wf]
            detail = (f"static={r['mpe_static']:.3f} "
                      f"PR2={r['mpe_online_nobias']:.3f} "
                      f"bias={r['mpe_online']:.3f} "
                      f"risk={r['mpe_online_risk']:.3f} | makespan "
                      f"bias={r['makespan_online']:.0f} "
                      f"risk={r['makespan_online_risk']:.0f}")
            marker = "regressed" if wins < need else "lost (within budget)"
            print(f"       {wf}: {marker} — {detail}")
    if not ok:
        print("-- GATE FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
