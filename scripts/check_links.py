"""Markdown link checker for README.md and docs/ (CI step, no network).

Checks every inline markdown link/image in the doc set:

* relative file links must point at files that exist in the repo
  (anchors are stripped; an ``#anchor`` on a missing file still fails);
* intra-document anchors (``#section``) must match a heading slug of the
  target document (GitHub slug rules: lowercase, punctuation dropped,
  spaces -> dashes);
* absolute http(s) URLs are NOT fetched — CI must not flake on someone
  else's server — but obviously malformed ones (no host) fail.

Exits non-zero listing every broken link as ``file:line: message``.
"""
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DOCS = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$")


def slugify(heading: str) -> str:
    """GitHub-style heading slug (enough of it for our own docs)."""
    text = re.sub(r"[`*_]", "", heading.strip().lower())
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(path: Path) -> set[str]:
    out = set()
    for line in path.read_text().splitlines():
        m = HEADING.match(line)
        if m:
            out.add(slugify(m.group(1)))
    return out


def main() -> int:
    errors = []
    for doc in DOCS:
        if not doc.exists():
            errors.append(f"{doc.relative_to(ROOT)}: document missing")
            continue
        for lineno, line in enumerate(doc.read_text().splitlines(), 1):
            for m in LINK.finditer(line):
                target = m.group(1)
                where = f"{doc.relative_to(ROOT)}:{lineno}"
                if target.startswith(("http://", "https://")):
                    if not re.match(r"https?://[\w.-]+", target):
                        errors.append(f"{where}: malformed URL {target!r}")
                    continue
                if target.startswith("mailto:"):
                    continue
                path_part, _, anchor = target.partition("#")
                dest = (doc.parent / path_part).resolve() if path_part \
                    else doc
                if path_part and not dest.exists():
                    errors.append(f"{where}: broken link {target!r} "
                                  f"(no such file {path_part!r})")
                    continue
                if anchor and dest.suffix == ".md":
                    if anchor not in anchors_of(dest):
                        errors.append(f"{where}: broken anchor "
                                      f"{target!r} (no heading "
                                      f"'#{anchor}' in {dest.name})")
    for e in errors:
        print(e)
    n_links = sum(len(LINK.findall(d.read_text()))
                  for d in DOCS if d.exists())
    print(f"checked {n_links} links across {len(DOCS)} documents: "
          f"{len(errors)} broken")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
